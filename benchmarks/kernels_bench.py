"""Kernel micro-benchmarks.

On this CPU container Pallas kernels run in interpret mode (Python-speed),
so wall-clock there is meaningless; what we report per kernel is
  * the HBM bytes moved by the kernel vs its bf16 XLA equivalent (the
    quantity the TPU roofline actually charges), and
  * wall time of the jnp reference path as a CPU sanity number.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.paper_tables import row, _time_us
from repro.core import quant, ternary
from repro.kernels import ref
from repro.launch.mesh import HBM_BW, PEAK_BF16_FLOPS


def bench_ternary_matmul():
    M, K, N = 256, 4096, 4096
    w = jax.random.normal(jax.random.PRNGKey(0), (K, N))
    t, scale = ternary.ternarize(w)
    wp = ternary.pack_ternary_2bit(t)
    x = jax.random.normal(jax.random.PRNGKey(1), (M, K), jnp.bfloat16)
    us = _time_us(jax.jit(ref.ternary_matmul_ref), x, wp, scale, n=5)
    bytes_packed = wp.size + M * K * 2 + M * N * 2
    bytes_bf16 = K * N * 2 + M * K * 2 + M * N * 2
    flops = 2 * M * K * N
    roof_packed = max(bytes_packed / HBM_BW, flops / PEAK_BF16_FLOPS) * 1e6
    roof_bf16 = max(bytes_bf16 / HBM_BW, flops / PEAK_BF16_FLOPS) * 1e6
    row("ternary_matmul_ref_cpu", us,
        f"M{M}xK{K}xN{N} hbm_bytes={bytes_packed} vs_bf16={bytes_bf16} "
        f"traffic_ratio={bytes_bf16/bytes_packed:.2f}x "
        f"tpu_roofline_us={roof_packed:.2f} vs_bf16_us={roof_bf16:.2f}")


def bench_dual_plane_matmul():
    M, K, N = 256, 2048, 2048
    k = jax.random.PRNGKey(0)
    qh, sh = quant.quantize_int4(jax.random.normal(k, (K, N)), axis=0)
    ql, sl = quant.quantize_int4(
        jax.random.normal(jax.random.fold_in(k, 1), (K, N)), axis=0)
    buf = quant.pack_int4_pair(qh, ql)
    x = jax.random.normal(jax.random.fold_in(k, 2), (M, K), jnp.bfloat16)
    us = _time_us(jax.jit(ref.dual_plane_matmul_ref), x, buf, sh, sl, n=5)
    bytes_dual = buf.size + M * K * 2 + 2 * M * N * 2
    bytes_two_bf16 = 2 * K * N * 2 + M * K * 2 + 2 * M * N * 2
    row("dual_plane_matmul_ref_cpu", us,
        f"two_matmuls_one_buffer traffic_ratio="
        f"{bytes_two_bf16/bytes_dual:.2f}x")


def bench_packed_kv_attention():
    B, KV, Hg, D, S = 8, 8, 4, 128, 8192
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, KV, Hg, D), jnp.bfloat16)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, S, D))
    kq, ks = quant.quantize_int4(kf, axis=-1)
    kp = quant.pack_int4_pair(kq[..., 0::2], kq[..., 1::2])
    vp, vs = kp, ks[..., 0].astype(jnp.bfloat16)
    ks2 = vs
    lengths = jnp.full((B,), S, jnp.int32)
    us = _time_us(jax.jit(ref.packed_kv_attention_ref), q, kp, vp, ks2, vs,
                  lengths, n=3)
    cache_packed = 2 * B * KV * S * (D // 2 + 2)
    cache_bf16 = 2 * B * KV * S * D * 2
    row("packed_kv_attention_ref_cpu", us,
        f"B{B}xKV{KV}xS{S}xD{D} cache_bytes={cache_packed} "
        f"vs_bf16={cache_bf16} traffic_ratio={cache_bf16/cache_packed:.2f}x "
        f"decode_roofline_us={cache_packed/HBM_BW*1e6:.1f} "
        f"vs_bf16_us={cache_bf16/HBM_BW*1e6:.1f}")


def run_all():
    bench_ternary_matmul()
    bench_dual_plane_matmul()
    bench_packed_kv_attention()
