"""Shared-prefix page-reuse bench: multi-turn chat sessions over one
common system prompt, prefix cache ON vs OFF — the BENCH_prefix.json
payload.

Workload: S sessions, each T turns, all sharing one page-aligned system
prompt. Turn k's prompt is the session's full context (system + every
user/assistant turn so far) — the production multi-turn shape where the
whole history is re-offered per request. With the prefix cache ON the
engine maps the cached run's physical pages by refcount and prefills
only the tail; OFF re-prefills everything.

Acceptance (asserted here, so a regression fails the bench run):
  * decode output is TOKEN-IDENTICAL between the two engines — sharing
    pages changes where prefill reads from, never what decode computes;
  * second and later requests over the 100%-shared system prompt incur
    ZERO prefill dispatches for the shared run (only the tail's);
  * at >= 4 sessions the cached engine issues >= 2x fewer total prefill
    dispatches than the no-sharing engine.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.paper_tables import row
from repro.configs import get_arch
from repro.launch.mesh import make_local_mesh
from repro.serve import Request, ServeEngine

PAGE, CHUNK = 8, 8
SYS_TOKENS = 32             # 4 full pages — 100% page-aligned shared run
USER_TOKENS, MAX_NEW = 6, 4


def _session_prompts(rng, cfg, sessions: int, turns: int):
    """Per-session token streams: a common system prompt + per-session
    user turns (generated tokens are appended by the driver)."""
    system = rng.integers(0, cfg.vocab, size=(SYS_TOKENS,)).astype(np.int32)
    users = [[rng.integers(0, cfg.vocab, size=(USER_TOKENS,))
              .astype(np.int32) for _ in range(turns)]
             for _ in range(sessions)]
    return system, users


def _drive_chat(eng: ServeEngine, system, users, turns: int) -> dict:
    """Run every session's turns to completion (turn k+1 re-offers the
    session's full context), recording prefill dispatches, TTFT proxy
    (admission wall time — where prefill runs), and peak bytes shared."""
    sessions = len(users)
    context = [system.copy() for _ in range(sessions)]
    outputs: dict[int, list[int]] = {}
    per_request_prefill: list[int] = []
    ttft_s: list[float] = []
    peak_shared = 0
    rid = 0
    for turn in range(turns):
        for s in range(sessions):
            context[s] = np.concatenate([context[s], users[s][turn]])
            before = eng.prefill_dispatch_count
            t0 = time.perf_counter()
            eng.add_request(Request(prompt=context[s].copy(),
                                    max_new_tokens=MAX_NEW, id=rid))
            ttft_s.append(time.perf_counter() - t0)
            per_request_prefill.append(eng.prefill_dispatch_count - before)
            if eng.store.kind == "paged":
                peak_shared = max(peak_shared, eng.store.bytes_shared())
            while eng.active.any() or eng._queue:
                eng.step_all()
            gen = np.asarray(eng.outputs[rid], np.int32)
            context[s] = np.concatenate([context[s], gen])
            outputs[rid] = list(map(int, gen))
            rid += 1
    st = eng.stats()
    return {
        "requests": rid,
        "outputs": outputs,
        "prefill_dispatches": eng.prefill_dispatch_count,
        "per_request_prefill_dispatches": per_request_prefill,
        "ttft_s_mean": float(np.mean(ttft_s)),
        "ttft_s_p99": float(np.percentile(ttft_s, 99)),
        "peak_bytes_shared": peak_shared,
        "prefix": st["prefix"],
    }


def bench_chat(seed: int, sessions: int, turns: int = 2,
               arch: str = "qwen1.5-0.5b", entries: int = 8) -> dict:
    """One ON-vs-OFF cell at `sessions` concurrent chat sessions."""
    base = get_arch(arch).reduced()
    cfg = dataclasses.replace(
        base, amc=dataclasses.replace(base.amc, page_size=PAGE))
    rng = np.random.default_rng(seed + 11)
    system, users = _session_prompts(rng, cfg, sessions, turns)
    runs = {}
    for label, pc in (("shared", entries), ("baseline", 0)):
        eng = ServeEngine(cfg, make_local_mesh(), max_batch=4,
                          max_seq=256, prefill_chunk=CHUNK, seed=1,
                          prefix_cache=pc)
        runs[label] = _drive_chat(eng, system, users, turns)
    on, off = runs["shared"], runs["baseline"]
    assert on["outputs"] == off["outputs"], (
        "prefix sharing changed decode output — COW / page aliasing bug")
    # 2nd+ first-turn requests fully share the system prompt: their
    # prefill covers ONLY the tail past it, never the shared run
    tail_fed = SYS_TOKENS + USER_TOKENS - 1 - SYS_TOKENS   # fed minus run
    expect_tail = -(-max(tail_fed, 0) // CHUNK)
    first_turn = on["per_request_prefill_dispatches"][1:sessions]
    assert all(d == expect_tail for d in first_turn), (
        f"shared-run prefill not skipped: {first_turn} vs {expect_tail}")
    saved = on["prefix"]["dispatches_saved"]
    assert saved > 0, "prefix cache saved zero dispatches on a hit workload"
    speedup = off["prefill_dispatches"] / max(on["prefill_dispatches"], 1)
    res = {
        "sessions": sessions, "turns": turns,
        "prefill_dispatches_shared": on["prefill_dispatches"],
        "prefill_dispatches_baseline": off["prefill_dispatches"],
        "prefill_dispatch_reduction_x": speedup,
        "dispatches_saved": saved,
        "hit_rate": on["prefix"]["hit_rate"],
        "hits": on["prefix"]["hits"],
        "misses": on["prefix"]["misses"],
        "cow_events": on["prefix"]["cow_events"],
        "peak_bytes_shared": on["peak_bytes_shared"],
        "ttft_s_mean_shared": on["ttft_s_mean"],
        "ttft_s_mean_baseline": off["ttft_s_mean"],
        "token_identical": True,
        "zero_shared_run_prefill_on_hits": True,
    }
    row(f"prefix_chat_{sessions}sessions", on["ttft_s_mean"] * 1e6,
        f"prefill_disp={on['prefill_dispatches']} "
        f"(baseline={off['prefill_dispatches']}, "
        f"{speedup:.2f}x fewer) hit_rate={res['hit_rate']:.2f} "
        f"saved={saved} cow={res['cow_events']} "
        f"bytes_shared_peak={res['peak_bytes_shared']}")
    return res


def bench_moe_identity(seed: int) -> dict:
    """Decode token-identity pin on the MoE family: routed experts read
    the same shared pages, so sharing must stay output-invariant there
    too (2 sessions, 1 turn — identity, not throughput)."""
    res = bench_chat(seed, sessions=2, turns=1,
                     arch="qwen3-moe-30b-a3b", entries=4)
    return {"token_identical": res["token_identical"],
            "dispatches_saved": res["dispatches_saved"]}


def run_all(*, seed: int = 0, tiny: bool = False) -> dict:
    config = {"arch": "qwen1.5-0.5b(reduced)", "page_size": PAGE,
              "prefill_chunk": CHUNK, "system_tokens": SYS_TOKENS,
              "user_tokens": USER_TOKENS, "max_new_tokens": MAX_NEW}
    sweeps = {}
    sessions = (4,) if tiny else (1, 4, 8)
    for s in sessions:
        sweeps[str(s)] = bench_chat(seed, sessions=s)
    at4 = sweeps.get("4")
    acceptance = {
        "token_identity": all(c["token_identical"] for c in sweeps.values()),
        "zero_shared_run_prefill_on_hits": all(
            c["zero_shared_run_prefill_on_hits"] for c in sweeps.values()),
        "dispatches_saved_positive": all(
            c["dispatches_saved"] > 0 for c in sweeps.values()),
        "reduction_at_4_sessions_x":
            at4["prefill_dispatch_reduction_x"] if at4 else None,
        "at_least_2x_fewer_at_4_sessions":
            bool(at4 and at4["prefill_dispatch_reduction_x"] >= 2.0),
    }
    assert acceptance["at_least_2x_fewer_at_4_sessions"], (
        f"prefix cache below 2x prefill-dispatch reduction at 4 sessions: "
        f"{at4 and at4['prefill_dispatch_reduction_x']:.2f}x")
    out = {"config": config, "sessions": sweeps, "acceptance": acceptance}
    if not tiny:
        out["moe_identity"] = bench_moe_identity(seed)
        acceptance["moe_token_identity"] = \
            out["moe_identity"]["token_identical"]
    return out


def main() -> None:
    import json
    print("name,us_per_call,derived")
    payload = run_all()
    print(json.dumps(payload["acceptance"], indent=2, default=str))


if __name__ == "__main__":
    main()
