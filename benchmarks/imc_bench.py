"""IMC bench: modeled energy/token + decode throughput vs activation
precision across the augmented-storage matrix (BENCH_imc.json).

Two sections:
  * "kernel": imc_dot parity vs the packed matmul goldens (bit-exact at
    8-bit activations) and the array event model of one decode-shaped
    matmul per storage format x activation precision — the in-array vs
    fetch energy ratio is the arXiv:1802.08601/2008.03378 headline.
  * "matrix": the real ServeEngine on a reduced config with
    matmul_impl="imc", swept over {normal, ternary, dual, int4} storage x
    activation precisions: decode steps/s (CPU interpret mode — relative
    only) and the ledger's modeled energy/token, with Normal-mode and
    Augmented-mode cache reads costed per their page modes.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.paper_tables import row
from repro.configs import get_arch
from repro.configs.base import AMCConfig
from repro.core import ternary
from repro.imc import energy
from repro.kernels import ops, ref

# storage mode -> engine-level AMC knobs (weights and/or KV augmented)
STORAGE_MATRIX = {
    "normal": dict(weight_mode="normal", kv_mode="normal"),
    "ternary": dict(weight_mode="ternary", kv_mode="normal"),
    "dual": dict(weight_mode="dual", kv_mode="normal"),
    "int4": dict(weight_mode="normal", kv_mode="int4"),
}
ABITS_SWEEP = (4, 8)


def bench_imc_kernel(seed: int = 0) -> dict:
    """Parity + event model of the bit-serial kernel itself."""
    M, K, N = 128, 512, 256
    rng = np.random.default_rng(seed)
    x = rng.integers(-127, 128, size=(M, K)).astype(np.float32)
    x[:, 0] = 127                       # absmax == qmax -> exact path
    x = jnp.asarray(x, jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(seed), (K, N))
    t, scale = ternary.ternarize(w)
    wp = ternary.pack_ternary_2bit(t)
    y = ops.imc_dot(x, wp, scale, fmt="ternary", abits=8)
    golden = ops.ternary_matmul(x, wp, scale)
    bit_exact = bool(np.array_equal(np.asarray(y, np.float32),
                                    np.asarray(golden, np.float32)))
    xr = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, K), jnp.bfloat16)
    dense = ref.ternary_matmul_ref(xr, wp, scale)
    errs = {a: ref.rel_err(ops.imc_dot(xr, wp, scale, fmt="ternary",
                                       abits=a), dense)
            for a in (1, 4, 8)}

    # decode-shaped (M=1) event/energy model per storage x abits
    Kd, Nd = 2048, 2048
    model = {}
    for storage in ("ternary", "dual", "int4", "int8"):
        for abits in (1, 4, 8):
            ev_imc = energy.matmul_events(1, Kd, Nd, storage=storage,
                                          impl="imc", abits=abits)
            ev_fetch = energy.matmul_events(1, Kd, Nd, storage=storage,
                                            impl="packed")
            e_imc, e_fetch = energy.energy_fj(ev_imc), energy.energy_fj(
                ev_fetch)
            model[f"{storage}/abits{abits}"] = {
                "imc_energy_fj": e_imc, "fetch_energy_fj": e_fetch,
                "imc_vs_fetch_ratio": e_imc / e_fetch,
            }
            row(f"imc_model_{storage}_abits{abits}", 0.0,
                f"imc_fj={e_imc:.0f} fetch_fj={e_fetch:.0f} "
                f"ratio={e_imc/e_fetch:.2f}")
    row("imc_dot_parity", 0.0,
        f"bit_exact_vs_ternary_matmul={bit_exact} "
        f"rel_err_abits148={errs[1]:.3f}/{errs[4]:.3f}/{errs[8]:.4f}")
    return {"bit_exact_vs_ternary_matmul": bit_exact,
            "rel_err_vs_dense_by_abits": {str(a): float(e)
                                          for a, e in errs.items()},
            "decode_matmul_model": model}


def bench_imc_matrix(seed: int = 0, tiny: bool = False) -> dict:
    """The engine-level matrix: storage mode x activation precision."""
    from repro.launch.mesh import make_local_mesh
    from repro.serve import Request, ServeEngine

    base = get_arch("qwen1.5-0.5b").reduced()
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, base.vocab, size=(5,)).astype(np.int32)
    storage = ({"int4": STORAGE_MATRIX["int4"]} if tiny
               else STORAGE_MATRIX)
    abits_sweep = (8,) if tiny else ABITS_SWEEP
    matrix = {}
    for sname, knobs in storage.items():
        for abits in abits_sweep:
            cfg = dataclasses.replace(
                base, amc=AMCConfig(matmul_impl="imc", imc_abits=abits,
                                    **knobs))
            eng = ServeEngine(cfg, make_local_mesh(), max_batch=2,
                              max_seq=32, prefill_chunk=16)
            eng.add_request(Request(prompt=prompt.copy(),
                                    max_new_tokens=24, id=0))
            eng.step_all()                   # warmup (compiles decode)
            tok0 = eng.energy_ledger.tokens
            fj0 = eng.energy_ledger.energy_fj()
            n, t0 = 6, time.perf_counter()
            for _ in range(n):
                eng.step_all()
            dt = time.perf_counter() - t0
            st = eng.stats()
            d_tok = eng.energy_ledger.tokens - tok0
            pj_tok = (eng.energy_ledger.energy_fj() - fj0) / max(d_tok,
                                                                 1) / 1e3
            key = f"{sname}/abits{abits}"
            matrix[key] = {
                "decode_steps_per_s": n / dt,
                "energy_pj_per_token_decode": pj_tok,
                "energy_pj_per_token_total":
                    st["imc"]["energy_pj_per_token"],
                "groups_energy_fj": {g: d["energy_fj"] for g, d in
                                     st["imc"]["groups"].items()},
                "kv_read_fj_per_value_normal_mode":
                    st["imc"]["kv_read_fj_per_value_normal_mode"],
                "kv_read_fj_per_value_augmented_mode":
                    st["imc"]["kv_read_fj_per_value_augmented_mode"],
                "capacity_factor": st["capacity_factor"],
            }
            row(f"imc_serve_{sname}_abits{abits}", dt / n * 1e6,
                f"steps_per_s={n/dt:.2f} energy_pj_per_tok={pj_tok:.1f}")
    return matrix


def run_all(*, seed: int = 0, tiny: bool = False) -> dict:
    """Returns the BENCH_imc.json payload. ``tiny`` keeps the analytic
    kernel/event section and a single matrix cell (int4 @ 8-bit)."""
    return {"kernel": bench_imc_kernel(seed),
            "matrix": bench_imc_matrix(seed, tiny=tiny),
            "event_energy_fj": dict(energy.EVENT_ENERGY_FJ)}
