"""End-to-end benches on reduced configs (CPU wall-clock, relative only):
train step/s, decode tokens/s with normal vs packed KV, AMC-Adam overhead.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.paper_tables import row
from repro.configs import get_arch
from repro.configs.base import AMCConfig, ShapeConfig
from repro.launch.mesh import make_local_mesh
from repro.models import model as M
from repro.models.params import init_params
from repro.optim import adamw
from repro.train import step as step_lib


def bench_train_step(seed: int = 0):
    cfg = get_arch("qwen1.5-0.5b").reduced()
    B, S = 4, 128
    params = init_params(M.abstract_params(cfg), jax.random.PRNGKey(seed))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed + 1),
                                          (B, S), 0, cfg.vocab),
             "targets": jax.random.randint(jax.random.PRNGKey(seed + 2),
                                           (B, S), 0, cfg.vocab)}
    for opt in ("adamw", "amc_adamw"):
        settings = step_lib.TrainSettings(optimizer=opt, q_chunk=64)
        init_fn, _ = adamw.make_optimizer(opt)
        state = step_lib.TrainState(params, init_fn(params),
                                    jnp.zeros((), jnp.int32))
        fn = jax.jit(step_lib.make_train_step(cfg, settings, rules=None))
        state, _ = fn(state, batch)  # compile
        t0 = time.perf_counter()
        for _ in range(5):
            state, loss = fn(state, batch)
        jax.block_until_ready(loss)
        us = (time.perf_counter() - t0) / 5 * 1e6
        opt_bytes = sum(x.nbytes for x in jax.tree.leaves(state.opt))
        row(f"train_step_{opt}", us,
            f"tokens={B*S} opt_state_bytes={opt_bytes}")


def bench_decode_kv_modes(seed: int = 0):
    base = get_arch("granite-3-2b").reduced()
    B, S = 4, 256
    shape = ShapeConfig("d", S, B, "decode")
    for mode in ("normal", "int8", "int4"):
        cfg = dataclasses.replace(base, amc=AMCConfig(kv_mode=mode))
        params = init_params(M.abstract_params(cfg),
                             jax.random.PRNGKey(seed))
        cache = jax.tree.map(
            lambda l: jnp.zeros(l.shape, l.jdtype),
            M.abstract_cache(cfg, shape),
            is_leaf=lambda x: hasattr(x, "jdtype"))
        fn = jax.jit(lambda p, c, b: M.decode_step(cfg, p, c, b),
                     donate_argnums=(1,))
        batch = {"tokens": jnp.ones((B, 1), jnp.int32),
                 "positions": jnp.zeros((B,), jnp.int32)}
        logits, cache = fn(params, cache, batch)  # compile
        t0 = time.perf_counter()
        n = 10
        for i in range(n):
            batch["positions"] = batch["positions"] + 1
            logits, cache = fn(params, cache, batch)
        jax.block_until_ready(logits)
        us = (time.perf_counter() - t0) / n * 1e6
        cache_bytes = sum(x.nbytes for x in jax.tree.leaves(cache))
        row(f"decode_step_kv_{mode}", us,
            f"cache_bytes={cache_bytes} tok_per_s={B/(us/1e6):.0f}")


def bench_serve_prefill_decode(seed: int = 0) -> dict:
    """Serving hot path on the reduced config: prefill tokens/sec with
    single-dispatch chunked prefill (vs the P-dispatch per-token loop),
    decode steps/sec through `step_all`, and the modeled HBM traffic of
    the packed cache. Returns the BENCH_serve.json payload."""
    from benchmarks.kernels_bench import serve_hbm_model
    from repro.launch.mesh import make_local_mesh
    from repro.serve import Request, ServeEngine

    cfg = get_arch("qwen1.5-0.5b").reduced()
    chunk, plen, new_tokens = 16, 33, 8
    eng = ServeEngine(cfg, make_local_mesh(), max_batch=2, max_seq=64,
                      prefill_chunk=chunk, seed=seed)
    rng = np.random.default_rng(seed)

    def mk(i):
        return Request(prompt=rng.integers(0, cfg.vocab, size=(plen,))
                       .astype(np.int32), max_new_tokens=new_tokens, id=i)

    # warmup (compiles the prefill and decode dispatch shapes)
    eng.add_request(mk(0))
    eng.step_all()

    d0, t0 = eng.dispatch_count, time.perf_counter()
    eng.add_request(mk(1))
    prefill_s = time.perf_counter() - t0
    prefill_dispatches = eng.dispatch_count - d0
    prefill_tokens = plen - 1
    row("serve_prefill", prefill_s * 1e6,
        f"tokens={prefill_tokens} dispatches={prefill_dispatches} "
        f"chunk={chunk} tok_per_s={prefill_tokens/prefill_s:.0f} "
        f"per_token_path_dispatches={prefill_tokens}")

    emitted0 = sum(len(v) for v in eng.outputs.values())
    n, t0 = 0, time.perf_counter()
    while eng.active.any():
        eng.step_all()
        n += 1
    decode_s = time.perf_counter() - t0
    emitted = sum(len(v) for v in eng.outputs.values()) - emitted0
    row("serve_decode", decode_s / max(n, 1) * 1e6,
        f"steps={n} steps_per_s={n/decode_s:.1f} "
        f"tok_per_s={emitted/decode_s:.0f}")

    st = eng.stats()
    return {
        "config": {"arch": "qwen1.5-0.5b(reduced)", "prefill_chunk": chunk,
                   "max_batch": 2, "max_seq": 64, "kv_mode": cfg.amc.kv_mode,
                   "weight_mode": cfg.amc.weight_mode,
                   "pool_mode": eng.pool.pool_mode},
        "prefill": {"tokens": prefill_tokens,
                    "dispatches": prefill_dispatches,
                    "per_token_path_dispatches": prefill_tokens,
                    "tokens_per_s": prefill_tokens / prefill_s},
        "decode": {"steps": n, "steps_per_s": n / decode_s,
                   "tokens_per_s": emitted / decode_s},
        "hbm_model": serve_hbm_model(kv_mode=cfg.amc.kv_mode,
                                     weight_mode=cfg.amc.weight_mode),
        # paged-pool refresh/maintenance traffic rides along so the
        # serving trajectory tracks the retention cost too
        "pool": st.get("pool"),
        "scheduler": st.get("scheduler"),
    }


def bench_serve_matrix(seed: int = 0) -> dict:
    """The kv_mode x weight_mode serving matrix on the reduced config:
    decode steps/s through the real engine (Pallas kernels in interpret
    mode on CPU — relative numbers only) plus the modeled full-scale
    per-decode-step HBM traffic, which is where the paper's augmentation
    ratio shows up. Returned as BENCH_serve.json's "matrix" section."""
    from benchmarks.kernels_bench import serve_hbm_model
    from repro.serve import Request, ServeEngine

    base = get_arch("qwen1.5-0.5b").reduced()
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, base.vocab, size=(5,)).astype(np.int32)
    matrix = {}
    for kv_mode in ("normal", "int8", "int4"):
        for weight_mode in ("normal", "ternary", "dual"):
            cfg = dataclasses.replace(
                base, amc=AMCConfig(weight_mode=weight_mode,
                                    kv_mode=kv_mode))
            eng = ServeEngine(cfg, make_local_mesh(), max_batch=2,
                              max_seq=32, prefill_chunk=16)
            eng.add_request(Request(prompt=prompt.copy(),
                                    max_new_tokens=24, id=0))
            eng.step_all()                       # warmup (compiles decode)
            n, t0 = 6, time.perf_counter()
            for _ in range(n):
                eng.step_all()
            dt = time.perf_counter() - t0
            key = f"{kv_mode}/{weight_mode}"
            st = eng.stats()
            matrix[key] = {
                "decode_steps_per_s": n / dt,
                "capacity_factor": st["capacity_factor"],
                "cache_bytes_physical": st["cache_bytes_physical"],
                "weight_bytes_physical": st["weight_bytes_physical"],
                "hbm_model": serve_hbm_model(kv_mode=kv_mode,
                                             weight_mode=weight_mode),
            }
            row(f"serve_matrix_{kv_mode}_{weight_mode}", dt / n * 1e6,
                f"steps_per_s={n/dt:.2f} "
                f"modeled_traffic_ratio="
                f"{matrix[key]['hbm_model']['traffic_ratio_vs_bf16']:.2f}x")
    return matrix


def bench_serve_speculative(seed: int = 0, tiny: bool = False) -> dict:
    """Self-speculative decoding sweep: spec_k x family, against the
    SAME requests at spec_k=1 (the stepwise baseline). Reports decode
    tokens/s wall-clock, useful-tokens-per-dispatch, and verifies the
    emitted streams are token-identical to stepwise — the accept/rollback
    guarantee, measured end-to-end. Engines are warmed up (all dispatch
    shapes compiled) before the timed run so interpret-mode compile cost
    stays out of the tokens/s numbers."""
    from repro.serve import Request, ServeEngine

    families = {"dense": ("qwen1.5-0.5b", dict(kv_mode="int4")),
                "moe": ("qwen3-moe-30b-a3b", dict(kv_mode="int4")),
                "ssm": ("mamba2-130m", {})}
    if tiny:
        families = {"dense": families["dense"]}
    spec_ks = (1, 2) if tiny else (1, 2, 4, 8)
    max_new = 8 if tiny else 16
    out: dict = {}
    for fam, (arch, knobs) in families.items():
        cfg = get_arch(arch).reduced()
        rng = np.random.default_rng(seed)
        prompts = [rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
                   for _ in range(3)]
        fam_out: dict = {}
        golden = None
        for k in spec_ks:
            eng = ServeEngine(cfg, make_local_mesh(), max_batch=3,
                              max_seq=64, prefill_chunk=16, spec_k=k,
                              seed=seed, **knobs)
            # warmup request compiles prefill + decode + draft + verify
            eng.generate([Request(prompt=prompts[0].copy(),
                                  max_new_tokens=2, id=999)])
            reqs = [Request(prompt=p.copy(), max_new_tokens=max_new, id=i)
                    for i, p in enumerate(prompts)]
            t0 = time.perf_counter()
            outs = eng.generate(reqs)
            dt = time.perf_counter() - t0
            outs = {i: outs[i] for i in range(len(prompts))}
            if golden is None:
                golden = outs
            sp = eng.stats()["spec"]
            tokens = sum(len(v) for v in outs.values())
            fam_out[f"spec_k={k}"] = {
                "tokens": tokens,
                "wall_s": dt,
                "tokens_per_s": tokens / dt,
                "accepted_tokens_per_dispatch":
                    sp["accepted_tokens_per_dispatch"],
                "accepted_tokens_per_round":
                    sp["accepted_tokens_per_round"],
                "draft_dispatches": sp["draft_dispatches"],
                "verify_dispatches": sp["verify_dispatches"],
                "token_identical_to_stepwise": outs == golden,
            }
            row(f"serve_spec_{fam}_k{k}", dt / max(tokens, 1) * 1e6,
                f"tok_per_s={tokens/dt:.2f} "
                f"acc_per_dispatch="
                f"{sp['accepted_tokens_per_dispatch']:.2f} "
                f"identical={outs == golden}")
        base_tps = fam_out[f"spec_k={spec_ks[0]}"]["tokens_per_s"]
        best = max(spec_ks[1:],
                   key=lambda k: fam_out[f"spec_k={k}"]["tokens_per_s"])
        fam_out["best_spec_k"] = best
        fam_out["best_speedup_vs_stepwise"] = (
            fam_out[f"spec_k={best}"]["tokens_per_s"] / base_tps)
        out[fam] = fam_out
    out["any_family_beats_stepwise"] = any(
        d["best_speedup_vs_stepwise"] > 1.0
        for d in out.values() if isinstance(d, dict))
    return out


def run_all(*, seed: int = 0, tiny: bool = False) -> dict:
    """Runs every e2e bench; returns the BENCH_serve.json payload.
    ``tiny`` keeps the serving hot path and a dense spec_k in {1, 2}
    speculative cell."""
    if tiny:
        payload = bench_serve_prefill_decode(seed)
        payload["speculative"] = bench_serve_speculative(seed, tiny=True)
        payload["tiny"] = True
        return payload
    bench_train_step(seed)
    bench_decode_kv_modes(seed)
    payload = bench_serve_prefill_decode(seed)
    payload["matrix"] = bench_serve_matrix(seed)
    payload["speculative"] = bench_serve_speculative(seed)
    return payload
