"""Benchmarks reproducing the paper's tables, TPU-adapted (DESIGN.md SS2).

Paper tables -> TPU analogs:
  Table I/II   retention vs temperature     -> LeakageModel curves + software
                                              retention-steps under e(T) noise
  Table III/IV read/write energy per mode   -> HBM bytes moved per access
  Table V/VI   read/write delay per mode    -> roofline time (bytes / BW)
  SS.I headline: augmented capacity         -> params/GiB + KV tokens/GiB
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dual_plane as dp
from repro.core import quant, ternary
from repro.core.retention import LeakageModel, V_SENSE_FRACTION
from repro.launch.mesh import HBM_BW

ROWS = []


def row(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def _time_us(fn, *args, n=20):
    # block the warmup result: otherwise compilation/dispatch may still be
    # in flight when the timer starts and the first timed call absorbs it
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


# ---------------------------------------------------------------------------
# Tables I & II — retention vs temperature
# ---------------------------------------------------------------------------

def bench_retention(seed: int = 0):
    for cell in ("8T", "7T"):
        m = LeakageModel(cell)
        for t in (85, 65, 45, 25):
            row(f"retention_{cell}_{t}C", 0.0,
                f"retention_us={m.retention_us(t):.1f}")
    # software analog: steps until sense failure under per-step noise e(T)
    # (noise sigma scales inversely with the paper's retention time)
    key = jax.random.PRNGKey(seed)
    level0 = jnp.ones((1024,))
    for t in (85, 25):
        m = LeakageModel("8T")
        sigma = 0.5 / (m.retention_us(t) / m.retention_us(85)) * 0.05
        level = level0
        steps = 0
        while float(jnp.mean(level)) > V_SENSE_FRACTION and steps < 10000:
            key, k = jax.random.split(key)
            level = level * (1 - sigma) - jnp.abs(
                jax.random.normal(k, level.shape)) * sigma * 0.1
            steps += 1
        row(f"retention_steps_sim_8T_{t}C", 0.0, f"steps={steps}")


# ---------------------------------------------------------------------------
# Tables III & IV — read/write "energy" (bytes moved per access)
# ---------------------------------------------------------------------------

def bench_energy_bytes(seed: int = 0):
    n = 1024 * 1024  # 1M logical values per access
    shape = (1024, 1024)
    x = jax.random.normal(jax.random.PRNGKey(seed), shape)

    # normal mode (6T analog): bf16 read/write
    bytes_normal = n * 2
    t_w = _time_us(jax.jit(lambda v: v.astype(jnp.bfloat16)), x)
    row("write_normal_bf16", t_w, f"bytes={bytes_normal}")
    # 8T augmented: static int4 write (+scale), dynamic int4 write
    d = dp.alloc(shape)
    t_ws = _time_us(jax.jit(lambda v: dp.write_static(dp.alloc(shape), v)), x)
    row("write_augmented_static_int4", t_ws,
        f"bytes={n} ratio_vs_normal={n/bytes_normal:.2f}")
    d = dp.write_static(d, x)
    t_wd = _time_us(jax.jit(lambda dd, v: dp.write_dynamic(dd, v)), d, x)
    row("write_augmented_dynamic_int4", t_wd,
        f"bytes={n} ratio_vs_normal={n/bytes_normal:.2f}")
    t_r = _time_us(jax.jit(dp.read_static), d)
    row("read_augmented_static", t_r, f"bytes={n}")
    t_rd = _time_us(jax.jit(dp.read_dynamic), d)
    row("read_augmented_dynamic", t_rd, f"bytes={n}")
    # 7T augmented: ternary write/read (base-3: 0.2 B/value; K % 5 == 0)
    xt = jax.random.normal(jax.random.PRNGKey(seed + 1), (1280, 1024))
    nt = xt.size
    t7_w = _time_us(jax.jit(
        lambda v: ternary.pack_ternary_base3(ternary.ternarize(v)[0])), xt)
    row("write_augmented_ternary_b3", t7_w,
        f"bytes={nt//5} ratio_vs_normal={nt/5/(nt*2):.3f}")
    packed = ternary.pack_ternary_base3(ternary.ternarize(xt)[0])
    t7_r = _time_us(jax.jit(
        lambda p: ternary.unpack_ternary_base3(p, xt.shape[0])), packed)
    row("read_augmented_ternary_b3", t7_r, f"bytes={nt//5}")


# ---------------------------------------------------------------------------
# Tables V & VI — read/write delay (roofline time on the target TPU)
# ---------------------------------------------------------------------------

def bench_op_latency():
    n = 1024 * 1024
    for name, bpv in (("normal_bf16", 2.0), ("augmented_dual_int4", 0.5),
                      ("augmented_ternary_2bit", 0.25),
                      ("augmented_ternary_base3", 0.2)):
        t_roof = n * bpv / HBM_BW * 1e6
        row(f"roofline_delay_read_{name}", 0.0,
            f"us_at_819GBps={t_roof:.3f} speedup_vs_bf16={2.0/bpv:.1f}x")


# ---------------------------------------------------------------------------
# Headline: capacity augmentation
# ---------------------------------------------------------------------------

def bench_capacity():
    gib = 2**30
    for name, bpv, factor in (("normal_bf16", 2.0, 1.0),
                              ("augmented_dual_int4", 0.5, 4.0),
                              ("augmented_int8", 1.0, 2.0),
                              ("augmented_ternary_2bit", 0.25, 8.0),
                              ("augmented_ternary_base3", 0.2, 10.0)):
        row(f"capacity_params_per_GiB_{name}", 0.0,
            f"params={gib/bpv:.3e} augmentation={factor}x")
    # KV tokens per GiB for granite-3-2b geometry (40L x 8KV x 64hd x 2 kv)
    per_tok_bf16 = 40 * 8 * 64 * 2 * 2
    per_tok_int4 = 40 * 8 * (64 // 2 + 2) * 2       # packed + bf16 scale
    row("kv_tokens_per_GiB_granite_bf16", 0.0, f"tokens={gib//per_tok_bf16}")
    row("kv_tokens_per_GiB_granite_int4", 0.0,
        f"tokens={gib//per_tok_int4} "
        f"augmentation={per_tok_bf16/per_tok_int4:.2f}x")


def run_all(*, seed: int = 0, tiny: bool = False) -> dict:
    """Runs every paper-table analog; returns the BENCH_paper_tables.json
    payload (the same rows the CSV prints, structured). The tables are
    analytic/cheap, so ``tiny`` only drops the timed byte-movement
    section."""
    ROWS.clear()
    bench_retention(seed)
    if not tiny:
        bench_energy_bytes(seed)
    bench_op_latency()
    bench_capacity()
    return {"rows": [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in ROWS]}
