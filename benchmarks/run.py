"""Benchmark harness — one section per paper table + kernel and e2e benches.
Prints ``name,us_per_call,derived`` CSV (see DESIGN.md SS7 experiment index)
and writes BENCH_serve.json (prefill/decode throughput, the kv_mode x
weight_mode serving matrix + modeled HBM traffic), BENCH_kernels.json
(per-kernel modeled bytes + Pallas-interpret parity),
BENCH_scheduler.json (pool modes x offered load + the per-family arch
sweep), BENCH_paper_tables.json (the Tables I-VI analog rows, structured)
BENCH_imc.json (storage matrix x activation precision: modeled
energy/token + throughput), BENCH_fault.json (retention-fault chaos
sweep: injection rates x recovery outcomes, with token identity to the
fault-free run asserted), BENCH_obs.json (observability overhead vs
the disabled Null facade + trace/metrics cross-validation) and
BENCH_prefix.json (shared-prefix page reuse: prefill dispatches saved,
hit rate, bytes shared, with decode token identity to the
sharing-disabled run asserted) so the serving perf trajectory is
tracked across PRs. BENCH_manifest.json
records run provenance: jax version/backend, seed, git sha and
per-emitter wall time.

A failing emitter no longer takes the others down silently: every section
runs, tracebacks are printed, the surviving payloads are written, and the
process exits non-zero if ANY emitter threw — CI fails loudly instead of
uploading a quietly truncated artifact set.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
import traceback


def _git_sha(root: str) -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
    except Exception:
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0,
                    help="base RNG seed threaded into every emitter "
                         "(prompt/request/weight randomness)")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke mode: each emitter runs a minimal "
                         "subset (single cells instead of full sweeps) "
                         "so the whole harness finishes in minutes")
    ap.add_argument("--num-arrays", type=int, nargs="+",
                    default=[1, 2, 4],
                    help="fleet sizes the scheduler emitter sweeps "
                         "(recorded in BENCH_manifest.json provenance)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    import functools

    import jax

    from benchmarks import e2e_bench, fault_bench, imc_bench, kernels_bench
    from benchmarks import obs_bench, paper_tables, prefix_bench
    from benchmarks import scheduler_bench
    scheduler_run = functools.partial(scheduler_bench.run_all,
                                      num_arrays=tuple(args.num_arrays))
    # the obs emitter measures a ~1% effect against run-to-run noise, so
    # it goes FIRST: after minutes of heavy sweeps the machine is hot
    # (frequency/cache state) and the measurement floor degrades
    sections = (
        ("BENCH_obs.json",
         "observability overhead + trace/metrics cross-validation",
         obs_bench.run_all),
        ("BENCH_paper_tables.json", "paper tables I-VI analogs",
         paper_tables.run_all),
        ("BENCH_kernels.json", "pallas kernels (bytes/roofline)",
         kernels_bench.run_all),
        ("BENCH_serve.json", "end-to-end (reduced configs, CPU)",
         e2e_bench.run_all),
        ("BENCH_scheduler.json",
         "continuous-batching scheduler (pool modes x load x arch "
         "x fleet size)",
         scheduler_run),
        ("BENCH_imc.json", "in-memory compute (storage x precision)",
         imc_bench.run_all),
        ("BENCH_fault.json",
         "retention-fault chaos (rates x recovery, token identity)",
         fault_bench.run_all),
        ("BENCH_prefix.json",
         "shared-prefix page reuse (multi-turn chat, COW + identity)",
         prefix_bench.run_all),
    )
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    failures: list[str] = []
    # run manifest: provenance + per-emitter wall time, written even when
    # emitters fail so a partial artifact set is still attributable
    # fleet/mesh provenance: the scheduler fleet sweep is only
    # reproducible given the array counts AND the device layout it
    # partitioned (one CPU device means arrays shared it)
    devices = jax.devices()
    manifest: dict = {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "seed": args.seed,
        "tiny": args.tiny,
        "git_sha": _git_sha(root),
        "num_arrays": list(args.num_arrays),
        "mesh": {
            "device_count": len(devices),
            "devices": [str(d) for d in devices[:8]],
            "local_mesh_shape": {"data": len(devices), "model": 1},
            "axes": ["data", "model"],
        },
        "emitters": {},
    }
    t_total = time.perf_counter()
    for name, title, emit in sections:
        print(f"# -- {title} --")
        t0 = time.perf_counter()
        try:
            payload = emit(seed=args.seed, tiny=args.tiny)
        except Exception:
            failures.append(name)
            manifest["emitters"][name] = {
                "wall_s": time.perf_counter() - t0, "ok": False}
            print(f"# EMITTER FAILED: {name}", file=sys.stderr)
            traceback.print_exc()
            continue
        manifest["emitters"][name] = {
            "wall_s": time.perf_counter() - t0, "ok": True}
        out = os.path.join(root, name)
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {out}")
    manifest["wall_s_total"] = time.perf_counter() - t_total
    mpath = os.path.join(root, "BENCH_manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"# wrote {mpath}")
    if failures:
        print(f"# FAILED emitters: {', '.join(failures)}", file=sys.stderr)
        sys.exit(1)
    print("# done")


if __name__ == "__main__":
    main()
