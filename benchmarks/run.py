"""Benchmark harness — one section per paper table + kernel and e2e benches.
Prints ``name,us_per_call,derived`` CSV (see DESIGN.md SS7 experiment index)
and writes BENCH_serve.json (prefill/decode throughput, the kv_mode x
weight_mode serving matrix + modeled HBM traffic), BENCH_kernels.json
(per-kernel modeled bytes + Pallas-interpret parity),
BENCH_scheduler.json (pool modes x offered load), BENCH_paper_tables.json
(the Tables I-VI analog rows, structured) and BENCH_imc.json (storage
matrix x activation precision: modeled energy/token + throughput) so the
serving perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import sys


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import e2e_bench, imc_bench, kernels_bench, paper_tables
    from benchmarks import scheduler_bench
    print("# -- paper tables I-VI analogs --")
    tables = paper_tables.run_all()
    print("# -- pallas kernels (bytes/roofline; CPU ref wall-time) --")
    kernels = kernels_bench.run_all()
    print("# -- end-to-end (reduced configs, CPU) --")
    serve = e2e_bench.run_all()
    print("# -- continuous-batching scheduler (pool modes x offered load) --")
    sched = scheduler_bench.run_all()
    print("# -- in-memory compute (storage matrix x activation precision) --")
    imc = imc_bench.run_all()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for name, payload in (("BENCH_serve.json", serve),
                          ("BENCH_kernels.json", kernels),
                          ("BENCH_scheduler.json", sched),
                          ("BENCH_paper_tables.json", tables),
                          ("BENCH_imc.json", imc)):
        out = os.path.join(root, name)
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {out}")
    print("# done")


if __name__ == "__main__":
    main()
