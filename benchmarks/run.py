"""Benchmark harness — one section per paper table + kernel and e2e benches.
Prints ``name,us_per_call,derived`` CSV (see DESIGN.md SS7 experiment index).
"""
from __future__ import annotations

import sys


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import e2e_bench, kernels_bench, paper_tables
    print("# -- paper tables I-VI analogs --")
    paper_tables.run_all()
    print("# -- pallas kernels (bytes/roofline; CPU ref wall-time) --")
    kernels_bench.run_all()
    print("# -- end-to-end (reduced configs, CPU) --")
    e2e_bench.run_all()
    print("# done")


if __name__ == "__main__":
    main()
