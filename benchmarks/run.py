"""Benchmark harness — one section per paper table + kernel and e2e benches.
Prints ``name,us_per_call,derived`` CSV (see DESIGN.md SS7 experiment index)
and writes BENCH_serve.json (prefill/decode throughput, the kv_mode x
weight_mode serving matrix + modeled HBM traffic) and BENCH_kernels.json
(per-kernel modeled bytes + Pallas-interpret parity) so the serving perf
trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import sys


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import e2e_bench, kernels_bench, paper_tables
    from benchmarks import scheduler_bench
    print("# -- paper tables I-VI analogs --")
    paper_tables.run_all()
    print("# -- pallas kernels (bytes/roofline; CPU ref wall-time) --")
    kernels = kernels_bench.run_all()
    print("# -- end-to-end (reduced configs, CPU) --")
    serve = e2e_bench.run_all()
    print("# -- continuous-batching scheduler (pool modes x offered load) --")
    sched = scheduler_bench.run_all()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for name, payload in (("BENCH_serve.json", serve),
                          ("BENCH_kernels.json", kernels),
                          ("BENCH_scheduler.json", sched)):
        out = os.path.join(root, name)
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {out}")
    print("# done")


if __name__ == "__main__":
    main()
