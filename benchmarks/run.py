"""Benchmark harness — one section per paper table + kernel and e2e benches.
Prints ``name,us_per_call,derived`` CSV (see DESIGN.md SS7 experiment index)
and writes BENCH_serve.json (prefill/decode throughput + modeled HBM
traffic for the packed cache) so the serving perf trajectory is tracked
across PRs.
"""
from __future__ import annotations

import json
import os
import sys


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import e2e_bench, kernels_bench, paper_tables
    print("# -- paper tables I-VI analogs --")
    paper_tables.run_all()
    print("# -- pallas kernels (bytes/roofline; CPU ref wall-time) --")
    kernels_bench.run_all()
    print("# -- end-to-end (reduced configs, CPU) --")
    serve = e2e_bench.run_all()
    out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_serve.json")
    with open(out, "w") as f:
        json.dump(serve, f, indent=2)
    print(f"# wrote {out}")
    print("# done")


if __name__ == "__main__":
    main()
