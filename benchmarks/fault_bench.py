"""Chaos harness: retention-fault injection vs self-healing serving —
the BENCH_fault.json payload.

Sweeps the fault rate over the dense always-augmented engine (the whole
decode state lives in the dynamic plane, so every page is at risk) and
proves, per rate, that recovery keeps the emitted token streams IDENTICAL
to the fault-free golden run: injected faults are detected by the
integrity words, healed by scrub or recompute-via-preemption, retried
with backoff — and nothing corrupt is ever served (the
`zero_silent_corruption` property from `stats()["faults"]`).

Rate 0 doubles as the no-overhead baseline: its tokens/s should match
BENCH_serve's throughput within noise (the fault machinery is inert with
no FaultModel attached). The rate sweep then prices the recovery tax —
extra decode steps, recovery energy, retries — as injection pressure
grows (the paper's Tables I-II tails made operational).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.paper_tables import row
from repro.configs import get_arch
from repro.configs.base import AMCConfig
from repro.launch.mesh import make_local_mesh
from repro.serve import Request, ServeEngine

ARCH = "qwen1.5-0.5b"
# rate 0 is the no-overhead baseline (tokens/s comparable to BENCH_serve);
# the upper rates are far past realistic tails so short CI runs still
# inject and recover from real corruption
RATES = (0.0, 0.05, 0.2, 0.5)
RATES_TINY = (0.0, 0.5)


def _reqs(rng, cfg, n, plen, max_new):
    return [Request(prompt=rng.integers(0, cfg.vocab, size=(plen,))
                    .astype(np.int32), max_new_tokens=max_new, id=i)
            for i in range(n)]


def _engine(cfg, mesh, *, max_batch, max_seq, retention_steps, **fault_kw):
    return ServeEngine(cfg, mesh, max_batch=max_batch, max_seq=max_seq,
                       prefill_chunk=16, retention_steps=retention_steps,
                       **fault_kw)


def _drive(eng, reqs):
    t0 = time.perf_counter()
    outs = eng.generate(reqs)
    dt = time.perf_counter() - t0
    return outs, dt


def run_all(*, seed: int = 0, tiny: bool = False) -> dict:
    cfg = dataclasses.replace(
        get_arch(ARCH).reduced(),
        amc=AMCConfig(pool_mode="always-augmented", kv_mode="int4"))
    mesh = make_local_mesh()
    # prompts span > 1 page so non-tail pages stop being rewritten and
    # genuinely AGE toward the retention cliff (a single-page row is
    # restamped every decode step and near-never faults)
    n_req, plen, max_new = (3, 20, 8) if tiny else (6, 24, 12)
    max_batch, max_seq, retention = 2, 64, 8
    rates = RATES_TINY if tiny else RATES
    rng = np.random.default_rng(seed)
    proto = _reqs(rng, cfg, n_req, plen, max_new)

    def fresh():
        return [Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                        id=r.id) for r in proto]

    # golden: fault-free run, the identity reference for every rate
    golden_eng = _engine(cfg, mesh, max_batch=max_batch, max_seq=max_seq,
                         retention_steps=retention)
    golden, golden_dt = _drive(golden_eng, fresh())
    golden_tokens = sum(len(v) for v in golden.values())

    sweep = []
    for rate in rates:
        eng = _engine(cfg, mesh, max_batch=max_batch, max_seq=max_seq,
                      retention_steps=retention,
                      fault_rate=rate, fault_seed=seed + 1)
        outs, dt = _drive(eng, fresh())
        st = eng.stats()
        fl = st["faults"]
        identical = (not eng.failed and all(
            np.array_equal(golden[i], outs[i]) for i in golden))
        tokens = sum(len(v) for v in outs.values())
        entry = {
            "fault_rate": rate,
            "token_identical_to_golden": bool(identical),
            "zero_silent_corruption": fl["zero_silent_corruption"],
            "tokens": tokens,
            "tokens_per_s": tokens / dt if dt else 0.0,
            "decode_steps": eng.step_idx,
            "dispatches": eng.dispatch_count,
            "faults_injected": fl["faults_injected"],
            "faults_detected": fl["faults_detected"],
            "faults_masked": fl["faults_masked"],
            "refresh_misses": fl["refresh_misses"],
            "recovered_scrub": fl["recovered_scrub"],
            "recovered_recompute": fl["recovered_recompute"],
            "retried": fl["retried"],
            "uncorrectable": fl["uncorrectable"],
            "failed_requests": fl["failed_requests"],
            "refreshes": st["refreshes"],
            "preemptions": st["preemptions"],
            "recovery_energy_fj": fl["recovery_energy_fj"],
            "refresh_energy_fj": st["imc"]["refresh_energy_fj"],
        }
        sweep.append(entry)
        row(f"fault/rate{rate:g}", dt * 1e6 / max(tokens, 1),
            f"identical={identical} injected={fl['faults_injected']} "
            f"recovered={fl['recovered']} "
            f"uncorrectable={fl['uncorrectable']}")
        assert identical, (
            f"rate={rate}: recovery broke token identity (outputs diverge "
            f"from the fault-free run)")
        assert fl["zero_silent_corruption"], (
            f"rate={rate}: silent corruption — injected faults neither "
            f"detected nor masked")

    # whole-array loss: forced event mid-run, drain-and-requeue recovery
    eng = _engine(cfg, mesh, max_batch=max_batch, max_seq=max_seq,
                  retention_steps=retention, fault_rate=0.0,
                  array_loss_rate=0.0)
    reqs = fresh()
    for r in reqs:
        eng.add_request(r)
    eng.step_all()
    eng.step_all()
    eng.inject_array_loss()
    while eng.active.any() or eng._queue:
        eng.step_all()
    fl = eng.stats()["faults"]
    loss_identical = all(np.array_equal(golden[i], eng.outputs[i])
                         for i in golden)
    row("fault/array_loss", 0.0,
        f"identical={loss_identical} requeued={fl['array_loss_requeues']}")
    assert loss_identical, "array-loss recovery broke token identity"

    return {
        "arch": ARCH,
        "pool_mode": "always-augmented",
        "kv_mode": "int4",
        "retention_steps": retention,
        "requests": n_req,
        "max_new_tokens": max_new,
        "golden_tokens": golden_tokens,
        "golden_tokens_per_s": golden_tokens / golden_dt,
        "rates": sweep,
        "array_loss": {
            "token_identical_to_golden": bool(loss_identical),
            "array_losses": fl["array_losses"],
            "array_loss_requeues": fl["array_loss_requeues"],
            "supervisor_restarts": fl["supervisor_restarts"],
        },
    }
